"""Parameter specification framework.

A model is described as a tree of :class:`ParamSpec`s.  One definition yields

* ``init(key, specs)``      -> real arrays (smoke tests / real training)
* ``abstract(specs)``       -> ``jax.ShapeDtypeStruct`` tree (dry-run: no allocation)
* ``logical_axes(specs)``   -> tree of logical-axis-name tuples, mapped to mesh
                               axes by :mod:`repro.dist.sharding`.

Logical axis vocabulary (see DESIGN.md §4):
  layers, stage, embed, mlp, heads, kv_heads, head_dim, qk_dim, v_dim,
  vocab, experts, expert_mlp, state, conv, pos, null
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Tree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Shape + dtype + init + logical axes for one parameter tensor."""

    shape: tuple[int, ...]
    dtype: Any = jnp.float32
    init: str = "normal"          # normal | zeros | ones | embed | scaled
    axes: tuple[str, ...] = ()    # logical axis names, len == len(shape)
    scale: float = 1.0            # stddev multiplier for normal/scaled init

    def __post_init__(self):
        if self.axes and len(self.axes) != len(self.shape):
            raise ValueError(
                f"axes {self.axes} do not match shape {self.shape}")


def _fan_in(shape: Sequence[int]) -> int:
    # For stacked-layer weights [L, in, out] the fan-in is the middle dim.
    if len(shape) >= 2:
        return shape[-2]
    return max(1, shape[-1])


def _init_one(key: jax.Array, spec: ParamSpec) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        std = 1.0 * spec.scale
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(spec.dtype)
    # normal / scaled: truncated-normal, std = scale / sqrt(fan_in)
    std = spec.scale / math.sqrt(_fan_in(spec.shape))
    x = jax.random.truncated_normal(key, -2.0, 2.0, spec.shape, jnp.float32)
    return (x * std).astype(spec.dtype)


def is_spec(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def init(key: jax.Array, specs: Tree) -> Tree:
    """Materialize a ParamSpec tree into real arrays."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [_init_one(k, s) for k, s in zip(keys, leaves)])


def abstract(specs: Tree) -> Tree:
    """ShapeDtypeStruct tree — used by the dry-run, never allocates."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=is_spec)


def logical_axes(specs: Tree) -> Tree:
    return jax.tree.map(lambda s: s.axes, specs, is_leaf=is_spec)


def n_params(specs: Tree) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=is_spec))


def bytes_of(specs: Tree) -> int:
    return sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
               for s in jax.tree.leaves(specs, is_leaf=is_spec))


def cast_tree(tree: Tree, dtype) -> Tree:
    def c(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x
    return jax.tree.map(c, tree)
