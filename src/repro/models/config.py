"""Unified architecture configuration for all assigned models + the paper's own."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Routed-experts config.

    ``expert_sharded`` opts a swarm pipeline into treating MoE stages
    as expert-sharded: the ``StagePlan`` then prices boundaries that
    *enter* such a stage per-token-routed (``top_k`` copies of every
    token cross the wire to the expert shards) instead of one uniform
    hidden-state transfer.  Off by default — dense-replica MoE stages
    keep the uniform pricing.
    """
    num_experts: int = 0              # routed experts
    num_shared: int = 0               # always-on shared experts (DeepSeek)
    top_k: int = 1
    d_ff_expert: int = 0              # per-expert FFN hidden size
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    aux_loss_coef: float = 0.01
    expert_sharded: bool = False      # expert-parallel stage placement


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 Multi-head Latent Attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0              # 0 => dense q projection
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 16               # per-channel SSM state (Mamba N)
    expand: int = 2                   # d_inner = expand * d_model
    conv_kernel: int = 4
    dt_rank: int = 0                  # 0 => ceil(d_model/16)
    chunk: int = 128                  # chunkwise-scan block for mLSTM/GLA forms


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Unified architecture config.

    Stage-plan inputs: ``block_kinds`` (derived from ``family`` or an
    explicit ``block_pattern``), ``share_groups``, and
    ``encoder_layers`` fully determine the per-stage structure a swarm
    pipeline runs — ``repro.models.stage_plan.make_stage_plan(cfg,
    n_stages)`` turns them into per-stage kind runs, boundary payload
    pricing, and aux-state slot ownership.  Mixed ``block_kinds`` with
    ``share_groups`` set is rejected (sharing across kinds is
    undefined); encoder-decoder configs plan stage 0 as the encoder pod
    and split decoder layers over the remaining stages.

    ``kernels`` selects the hot-path backend for every execution path
    that reads this config (the four runtime executors, the GSPMD
    pipeline, and the single-process step): ``"jnp"`` is the oracle
    math, ``"pallas"`` the fused kernels in ``repro.kernels`` — a pure
    backend switch, identical numerics within float tolerance.
    ``wire_quant`` additionally int8-quantizes the learned codec's wire
    tensor (a *semantic* switch: it changes what crosses the boundary,
    identically on both backends).
    """
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 => d_model // n_heads
    # --- attention flavor ---
    rope: str = "rope"               # rope | mrope | none
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    sliding_window: int = 0          # 0 => full attention
    attn_logit_softcap: float = 0.0
    causal: bool = True
    # --- ffn flavor ---
    act: str = "swiglu"              # swiglu | geglu | gelu
    # --- structure ---
    tie_embeddings: bool = False
    share_groups: int = 0            # ALBERT-style sharing (paper §4.3): 0=off
    scale_embed: bool = False        # gemma-style sqrt(d) embedding scale
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    block_pattern: Optional[tuple[str, ...]] = None  # per-layer block kinds
    # --- sub-configs ---
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # --- enc-dec (whisper) ---
    encoder_layers: int = 0          # >0 => encoder-decoder
    encoder_max_len: int = 1500      # whisper conv-stub frame cap
    # --- modality frontend stub ---
    frontend: str = "none"           # none | audio_stub | vision_stub
    # --- numerics ---
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # --- SWARM integration (paper technique knobs) ---
    boundary_compression: str = "int8"   # none | int8 | bottleneck | maxout
    bottleneck_dim: int = 0          # learned-codec wire width c (0 => d/2)
    maxout_k: int = 0                # maxout pool width (0 => derived; see
                                     # repro.compression.codecs.maxout_k)
    pipeline_stages: int = 0         # declared pipeline depth: >1 attaches
                                     # the stage-stacked learned-codec params
                                     # to model_specs (one pair per boundary)
    kernels: str = "jnp"             # hot-path backend: "jnp" (default) runs
                                     # the pure-jnp reference math; "pallas"
                                     # routes flash attention, rmsnorm and
                                     # the boundary codec through the fused
                                     # repro.kernels Pallas kernels (same
                                     # math, auto-interpreted off TPU/GPU —
                                     # see repro.kernels.backend)
    wire_quant: bool = False         # blockwise-int8 quantize the LEARNED
                                     # codec's c-dim wire tensor in both
                                     # directions (activations fwd,
                                     # cotangents bwd, straight-through
                                     # across rounding) — the paper's §4.3
                                     # quantize-on-send applied on top of
                                     # bottleneck/maxout; no-op for
                                     # none/int8 boundary modes
    # --- max positions for serving ---
    max_seq_len: int = 1 << 20

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def param_jdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def compute_jdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def is_subquadratic(self) -> bool:
        """True if the arch can serve a 500k-token context (see DESIGN.md §5)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0

    @property
    def block_kinds(self) -> tuple[str, ...]:
        if self.block_pattern is not None:
            return self.block_pattern
        kind = {
            "dense": "attn",
            "vlm": "attn",
            "audio": "attn",
            "moe": "moe",
            "ssm": "ssm",
            "hybrid": "hymba",
        }[self.family]
        return (kind,) * self.n_layers

    def with_overrides(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    n_layers = min(cfg.n_layers, 2 if cfg.encoder_layers == 0 else 2)
    kv = max(1, min(cfg.n_kv_heads, 2))
    heads = max(kv, 4)
    heads = (heads // kv) * kv
    kw = dict(
        n_layers=n_layers,
        d_model=64,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=16,
        d_ff=0 if cfg.d_ff == 0 else 128,
        vocab_size=512,
        encoder_layers=min(cfg.encoder_layers, 2),
        encoder_max_len=16,
        compute_dtype="float32",
        param_dtype="float32",
        max_seq_len=4096,
    )
    if cfg.sliding_window:
        kw["sliding_window"] = 8
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2),
            num_shared=min(cfg.moe.num_shared, 1), d_ff_expert=32)
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
                              v_head_dim=16)
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, state_dim=8, chunk=16)
    if cfg.block_pattern is not None:
        kw["block_pattern"] = cfg.block_pattern[:n_layers]
    if cfg.share_groups:
        kw["share_groups"] = n_layers  # one layer per group in smoke tests
    if cfg.bottleneck_dim:
        kw["bottleneck_dim"] = 32      # preserve the 64 -> c compression
    if cfg.pipeline_stages:
        kw["pipeline_stages"] = 2      # match the reduced 2-layer stack
    return cfg.with_overrides(**kw)
