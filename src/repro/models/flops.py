"""Analytic FLOP / byte accounting shared by the SWARM cost model and the
roofline analysis.

Conventions: matmul = 2mnk FLOPs; forward-only counts are per token;
``train_flops = 3x forward`` (fwd + 2x bwd, Kaplan et al.) and activation
checkpointing adds one forward recompute where stated.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.models.config import ArchConfig


def _attn_proj_flops(cfg: ArchConfig) -> float:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return 2 * d * hd * (2 * H + 2 * KV)        # q,o: H; k,v: KV


def _attn_ctx_flops(cfg: ArchConfig, ctx: float) -> float:
    H, hd = cfg.n_heads, cfg.hd
    return 2 * 2 * ctx * H * hd                  # scores + weighted sum


def _ffn_flops(cfg: ArchConfig, d_ff: Optional[int] = None) -> float:
    f = cfg.d_ff if d_ff is None else d_ff
    mults = 3 if cfg.act in ("swiglu", "geglu") else 2
    return 2 * mults * cfg.d_model * f


def _moe_flops(cfg: ArchConfig) -> float:
    m = cfg.moe
    d = cfg.d_model
    per_expert = 2 * 3 * d * m.d_ff_expert
    shared = 2 * 3 * d * (m.num_shared * m.d_ff_expert) if m.num_shared else 0
    router = 2 * d * m.num_experts
    return router + m.top_k * per_expert + shared


def _mla_flops(cfg: ArchConfig, ctx: float) -> float:
    a = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qd = a.qk_nope_dim + a.qk_rope_dim
    q = (2 * d * a.q_lora_rank + 2 * a.q_lora_rank * H * qd
         if a.q_lora_rank else 2 * d * H * qd)
    kv = 2 * d * a.kv_lora_rank + 2 * d * a.qk_rope_dim
    expand = 2 * a.kv_lora_rank * H * (a.qk_nope_dim + a.v_head_dim)
    attn = 2 * ctx * H * (qd + a.v_head_dim)
    out = 2 * H * a.v_head_dim * d
    return q + kv + expand + attn + out


def _mamba_flops(cfg: ArchConfig) -> float:
    s = cfg.ssm
    d = cfg.d_model
    di = s.expand * d
    dtr = s.dt_rank or -(-d // 16)
    proj = 2 * d * 2 * di + 2 * di * (dtr + 2 * s.state_dim) \
        + 2 * dtr * di + 2 * di * d
    scan = 10 * di * s.state_dim                 # discretize+scan+readout
    conv = 2 * s.conv_kernel * di
    return proj + scan + conv


def _mlstm_flops(cfg: ArchConfig, chunk: int) -> float:
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    proj = 2 * d * (3 * H * hd + 2 * H) + 2 * d * d + 2 * H * hd * d
    # chunkwise: intra-chunk attention ~2*2*chunk*H*hd + state update
    intra = 4 * chunk * H * hd
    state = 6 * H * hd * (hd + 1)
    return proj + intra + state


def _slstm_flops(cfg: ArchConfig) -> float:
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    return 2 * d * 4 * d + 2 * H * hd * 4 * hd + 2 * d * d + 20 * d


def cross_attn_flops(cfg: ArchConfig, enc_ctx: float) -> float:
    """Per decoder token: cross-attention scores/values over ``enc_ctx``
    encoder frames plus the q/o projections."""
    return (2 * 2 * enc_ctx * cfg.n_heads * cfg.hd
            + 4 * cfg.d_model * cfg.n_heads * cfg.hd)


def per_token_layer_flops(cfg: ArchConfig, kind: str, ctx: float,
                          enc_ctx: Optional[float] = None) -> float:
    """Forward FLOPs for one token through one block of ``kind`` with
    attention context ``ctx`` (= kv length actually attended).

    Whisper kinds: ``whisper_enc`` is a non-causal encoder block priced
    per encoder frame (pass ``ctx`` = encoder frames); ``whisper_dec``
    adds cross-attention over ``enc_ctx`` frames (defaults to
    ``cfg.encoder_max_len``) to a causal decoder block.
    """
    if kind == "whisper_enc":
        return _attn_proj_flops(cfg) + _attn_ctx_flops(cfg, ctx) \
            + _ffn_flops(cfg)
    if kind == "whisper_dec":
        ec = float(cfg.encoder_max_len) if enc_ctx is None else enc_ctx
        return _attn_proj_flops(cfg) + _attn_ctx_flops(cfg, ctx) \
            + _ffn_flops(cfg) + cross_attn_flops(cfg, ec)
    if kind == "attn":
        return _attn_proj_flops(cfg) + _attn_ctx_flops(cfg, ctx) \
            + _ffn_flops(cfg)
    if kind == "moe":
        return _attn_proj_flops(cfg) + _attn_ctx_flops(cfg, ctx) \
            + _moe_flops(cfg)
    if kind == "mla":
        return _mla_flops(cfg, ctx) + _ffn_flops(cfg)
    if kind == "mla_moe":
        return _mla_flops(cfg, ctx) + _moe_flops(cfg)
    if kind == "mlstm":
        return _mlstm_flops(cfg, cfg.ssm.chunk if cfg.ssm else 128)
    if kind == "slstm":
        return _slstm_flops(cfg)
    if kind == "hymba":
        return (_attn_proj_flops(cfg) + _attn_ctx_flops(cfg, ctx)
                + _mamba_flops(cfg) + _ffn_flops(cfg))
    if kind == "mamba":
        return _mamba_flops(cfg)
    raise KeyError(kind)


def _ctx_for(cfg: ArchConfig, seq: int, causal_avg: bool) -> float:
    ctx = seq / 2 if (causal_avg and cfg.causal) else seq
    if cfg.sliding_window:
        ctx = min(ctx, cfg.sliding_window)
    return float(ctx)


def forward_flops_per_token(cfg: ArchConfig, seq: int) -> float:
    """Whole-model forward FLOPs per token at train/prefill time."""
    ctx = _ctx_for(cfg, seq, causal_avg=True)
    total = sum(per_token_layer_flops(cfg, k, ctx) for k in cfg.block_kinds)
    if cfg.encoder_layers:       # whisper: encoder runs over its own frames
        enc_ctx = min(seq, cfg.encoder_max_len)
        total += cfg.encoder_layers * per_token_layer_flops(
            cfg, "whisper_enc", enc_ctx)
        # decoder cross-attention
        total += cfg.n_layers * cross_attn_flops(cfg, enc_ctx)
    total += 2 * cfg.d_model * cfg.vocab_size    # lm head
    return total


def decode_flops_per_token(cfg: ArchConfig, kv_len: int) -> float:
    ctx = _ctx_for(cfg, kv_len, causal_avg=False)
    total = sum(per_token_layer_flops(cfg, k, ctx) for k in cfg.block_kinds)
    if cfg.encoder_layers:
        total += cfg.n_layers * cross_attn_flops(
            cfg, float(cfg.encoder_max_len))
    total += 2 * cfg.d_model * cfg.vocab_size
    return total


def train_step_flops(cfg: ArchConfig, seq: int, global_batch: int) -> float:
    """fwd + bwd (2x) for one optimizer step (no remat recompute)."""
    return 3.0 * forward_flops_per_token(cfg, seq) * seq * global_batch


def model_flops_6nd(n_active_params: float, tokens: float) -> float:
    """The 6·N·D convention (MoE: N = activated params)."""
    return 6.0 * n_active_params * tokens


def boundary_bytes(cfg: ArchConfig, batch: int, seq: int,
                   compression: str = "none") -> float:
    """Bytes crossing one pipeline-stage boundary, one direction.

    Per-codec wire formulas (T = batch * seq tokens, d = d_model, 2-byte
    bf16 wire elements; one source of truth with what the execution paths
    actually emit — asserted by ``benchmarks/bench_compression.py``):

    * ``none``        2 * T * d
    * ``int8``        ``quant8.compressed_nbytes(T * d)``
                      = T*d codes + 4 bytes per ceil(T*d / BLOCK) block
    * ``bottleneck``  2 * T * c,       c = ``cfg.bottleneck_dim`` (0 => d/2)
    * ``maxout``      2 * T * (d / k), k = ``cfg.maxout_k`` (0 => derived —
                      see ``repro.compression.codecs.maxout_k``)

    Under ``cfg.wire_quant`` the learned codecs' c-dim wire additionally
    crosses as int8 codes + f32 per-block scales (block =
    ``codecs.wire_qblock``): T*c + 4 * T * (c / qb) bytes.
    """
    from repro.compression import codecs, quant8   # lazy: keep module light
    tokens = batch * seq
    if compression == "int8":
        return float(quant8.compressed_nbytes(tokens * cfg.d_model))
    c = codecs.wire_dim(cfg, compression)
    if compression in codecs.LEARNED and cfg.wire_quant:
        qb = codecs.wire_qblock(cfg, compression)
        return float(tokens * c + 4.0 * tokens * (c // qb))
    return 2.0 * tokens * c


def wire_nbytes(n_elements: float, compression: str = "none") -> float:
    """Wire bytes for ``n_elements`` hidden-state elements under a
    codec — the per-leaf primitive behind ``StagePlan.boundary_bytes``
    (2-byte bf16 elements; int8 adds per-block scales).  Learned codecs
    reshape a specific tensor, so they are priced by ``boundary_bytes``
    only."""
    from repro.compression import quant8                # lazy
    if compression == "int8":
        return float(quant8.compressed_nbytes(int(n_elements)))
    return 2.0 * n_elements


def stage_flops_per_token(cfg: ArchConfig, n_stages: int, s: int,
                          seq: int) -> float:
    """Per-kind forward FLOPs/token for pipeline stage ``s`` under the
    canonical ``StagePlan`` — summing over stages reproduces
    ``forward_flops_per_token`` exactly (asserted by
    ``benchmarks/bench_cost.py``)."""
    from repro.models.stage_plan import get_stage_plan  # lazy: no cycle
    return get_stage_plan(cfg, n_stages).stage_flops(s, seq)


def active_params(cfg: ArchConfig) -> float:
    """Per-token activated parameter count (MoE counts top_k + shared)."""
    from repro.train.steps import model_specs
    from repro.models import params as P
    specs = model_specs(cfg)
    total = P.n_params(specs)
    if cfg.moe is None:
        if cfg.share_groups:
            total += 0  # stored params already deduplicated
        return float(total)
    # subtract inactive experts
    m = cfg.moe
    d, f = cfg.d_model, m.d_ff_expert
    per_expert = 3 * d * f
    n_moe_layers = sum(1 for k in cfg.block_kinds if k in ("moe", "mla_moe"))
    inactive = n_moe_layers * (m.num_experts - m.top_k) * per_expert
    return float(total - inactive)


def total_params(cfg: ArchConfig) -> float:
    from repro.train.steps import model_specs
    from repro.models import params as P
    return float(P.n_params(model_specs(cfg)))
