"""LAMB optimizer (You et al., 2020) — the paper trains its 1B model with
LAMB at batch 16384 (App. G)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.optim.adamw import Optimizer, _zeros_like_f32

Tree = Any


def lamb(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.95,
         eps: float = 1e-6, weight_decay: float = 0.01,
         trust_clip: float = 10.0) -> Optimizer:
    def init(params: Tree) -> Tree:
        return {"m": _zeros_like_f32(params), "v": _zeros_like_f32(params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads: Tree, state: Tree, params: Tree):
        count = state["count"] + 1
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                         state["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                         state["v"], grads)

        def upd(m, v, p):
            mh = m / (1 - b1 ** count)
            vh = v / (1 - b2 ** count)
            u = mh / (jnp.sqrt(vh) + eps) \
                + weight_decay * p.astype(jnp.float32)
            pn = jnp.sqrt(jnp.sum(jnp.square(p.astype(jnp.float32))))
            un = jnp.sqrt(jnp.sum(jnp.square(u)))
            trust = jnp.where((pn > 0) & (un > 0),
                              jnp.clip(pn / un, 0.0, trust_clip), 1.0)
            return (-lr * trust * u).astype(p.dtype)

        updates = jax.tree.map(upd, m, v, params)
        return updates, {"m": m, "v": v, "count": count}

    return Optimizer(init, update)
