from repro.optim.adamw import adamw
from repro.optim.lamb import lamb
from repro.optim.dpu import delayed_parameter_updates

__all__ = ["adamw", "lamb", "delayed_parameter_updates"]
