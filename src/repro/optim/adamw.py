"""Minimal functional AdamW (optax-style triple: init / update)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

Tree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Tree], Tree]
    update: Callable[[Tree, Tree, Tree], tuple[Tree, Tree]]


def _zeros_like_f32(t: Tree) -> Tree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), t)


def adamw(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.01,
          grad_clip: float = 1.0, state_dtype=jnp.float32) -> Optimizer:
    """state_dtype=bfloat16 halves optimizer memory (beyond-paper lever;
    moments tolerate bf16 — the update math still runs in f32)."""
    def init(params: Tree) -> Tree:
        z = lambda t: jax.tree.map(
            lambda p: jnp.zeros(p.shape, state_dtype), t)
        return {"m": z(params), "v": z(params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads: Tree, state: Tree, params: Tree):
        count = state["count"] + 1
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if grad_clip > 0:
            gnorm = jnp.sqrt(sum(jnp.sum(g * g)
                                 for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        m = jax.tree.map(
            lambda m, g: (b1 * m.astype(jnp.float32)
                          + (1 - b1) * g).astype(state_dtype),
            state["m"], grads)
        v = jax.tree.map(
            lambda v, g: (b2 * v.astype(jnp.float32)
                          + (1 - b2) * g * g).astype(state_dtype),
            state["v"], grads)
        mh = jax.tree.map(
            lambda m: m.astype(jnp.float32) / (1 - b1 ** count), m)
        vh = jax.tree.map(
            lambda v: v.astype(jnp.float32) / (1 - b2 ** count), v)
        updates = jax.tree.map(
            lambda mh, vh, p: (-lr * (mh / (jnp.sqrt(vh) + eps)
                                      + weight_decay * p.astype(jnp.float32))
                               ).astype(p.dtype),
            mh, vh, params)
        return updates, {"m": m, "v": v, "count": count}

    return Optimizer(init, update)
