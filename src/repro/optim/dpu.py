"""Delayed Parameter Updates (Ren et al., 2021), as used by SWARM (§3.2).

The optimizer step for batch ``t`` is applied while batch ``t+1`` computes —
semantically the model at step ``t+1`` still sees the pre-update parameters
of step ``t``.  We reproduce exactly that one-step staleness: ``update``
returns the update computed from the *previous* step's gradients and banks
the current gradients for the next call.  With ``delay=0`` this is the
wrapped optimizer (App. E: disabling DPU makes SWARM fully synchronous).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.optim.adamw import Optimizer

Tree = Any


def delayed_parameter_updates(inner: Optimizer, delay: int = 1) -> Optimizer:
    if delay == 0:
        return inner

    def init(params: Tree) -> Tree:
        return {
            "inner": inner.init(params),
            "banked": jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "have_banked": jnp.zeros((), jnp.bool_),
        }

    def update(grads: Tree, state: Tree, params: Tree):
        banked, have = state["banked"], state["have_banked"]
        upd, inner_state = inner.update(banked, state["inner"], params)
        # first step: no banked grads yet -> apply zero update
        upd = jax.tree.map(
            lambda u: jnp.where(have, u, jnp.zeros_like(u)), upd)
        new_state = {
            "inner": jax.tree.map(
                lambda new, old: jnp.where(have, new, old),
                inner_state, state["inner"]),
            "banked": jax.tree.map(lambda g: g.astype(jnp.float32), grads),
            "have_banked": jnp.ones((), jnp.bool_),
        }
        return upd, new_state

    return Optimizer(init, update)
