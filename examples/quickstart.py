"""Quickstart: train a small LM with SWARM parallelism on CPU.

Spins up 2 pipeline stages x 2 peers + 3 trainer processes on the
virtual clock, with real JAX math and 8-bit compressed stage boundaries,
and shows the loss falling — then kills a peer mid-run to show nothing
breaks.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import SwarmRunner, SwarmConfig, TraceEvent
from repro.models.config import ArchConfig
from repro.optim import adamw


def main():
    cfg = ArchConfig(name="quickstart-lm", family="dense", n_layers=4,
                     d_model=128, n_heads=4, n_kv_heads=2, d_ff=512,
                     vocab_size=512, head_dim=32,
                     compute_dtype="float32", param_dtype="float32")
    scfg = SwarmConfig(n_stages=2, microbatch_size=4, seq_len=64,
                       global_batch=16, n_trainers=3,
                       rebalance_period=30.0, codec="int8", max_steps=10)
    runner = SwarmRunner(cfg, scfg, adamw(lr=3e-3), numeric=True, seed=0)
    runner.build(peers_per_stage=2)
    # a preemption one virtual second in: SWARM reroutes and keeps going
    runner.apply_trace([TraceEvent(1.0, -1)])

    print("training a 4-layer LM across a 2-stage swarm "
          "(int8 boundaries, 1 preemption)...")
    metrics = runner.run(until=1e9)
    for i, loss in enumerate(metrics["loss"]):
        print(f"  step {i + 1}: loss {loss:.4f}")
    print(f"peers failed: {metrics['failures']}, "
          f"migrations: {metrics['migrations']}, "
          f"throughput: {runner.throughput():.2f} samples/s (virtual)")
    assert metrics["loss"][-1] < metrics["loss"][0], "loss did not fall"
    print("OK — loss fell despite the failure.")


if __name__ == "__main__":
    main()
