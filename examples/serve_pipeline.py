"""Serving demo: batched prefill -> greedy decode with the production
step functions (prefill emits the decode caches; ring-buffer SWA caches
keep sliding-window archs O(window)).

    PYTHONPATH=src python examples/serve_pipeline.py [--arch yi-6b]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_reduced, ASSIGNED
from repro.optim import adamw
from repro.train.steps import (make_prefill_step, make_serve_step,
                               make_state)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b",
                    choices=ASSIGNED)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)          # CPU-sized, same family
    print(f"serving {args.arch} (reduced config: {cfg.n_layers}L "
          f"d={cfg.d_model})")
    state = make_state(cfg, adamw(), jax.random.PRNGKey(0))
    params = state["params"]

    key = jax.random.PRNGKey(1)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size)
    batch = {"tokens": prompts}
    if cfg.rope == "mrope":
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(args.prompt_len), (3, args.batch, args.prompt_len))
    if cfg.family == "audio":
        batch["audio_embed"] = jax.random.normal(
            key, (args.batch, cfg.encoder_max_len, cfg.d_model),
            cfg.compute_jdtype)

    # prefill with room for the generated tokens in the cache
    from repro.train.steps import decode_cache_specs
    from repro.configs import ShapeSpec
    total = args.prompt_len + args.new_tokens
    prefill = jax.jit(make_prefill_step(cfg))
    serve = jax.jit(make_serve_step(cfg))

    t0 = time.time()
    tok, caches = prefill(params, batch)
    # pad caches to the full decode horizon
    specs = decode_cache_specs(cfg, ShapeSpec("d", total, args.batch,
                                              "decode"))
    caches = jax.tree.map(
        lambda c, s: jnp.zeros(s.shape, s.dtype).at[
            tuple(slice(0, d) for d in c.shape)].set(c)
        if c.shape != s.shape else c, caches, specs)
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    for pos in range(args.prompt_len, total - 1):
        tok, caches = serve(params, caches, tok, jnp.int32(pos))
        out.append(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"prefill {args.batch}x{args.prompt_len} tokens: "
          f"{t_prefill * 1e3:.0f} ms")
    print(f"decode {gen.shape[1]} tokens/seq: "
          f"{t_decode * 1e3 / max(gen.shape[1], 1):.1f} ms/token (CPU)")
    print("generated token ids (seq 0):", gen[0].tolist())


if __name__ == "__main__":
    main()
