"""Serving demo: batched prefill -> greedy decode through the session
program API (``repro.serve``).  The prefill allocates its decode caches
at the full session horizon, so decoding writes in place — no cache
re-padding between prefill and decode.

    PYTHONPATH=src python examples/serve_pipeline.py [--arch yi-6b]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_reduced, ASSIGNED
from repro.models import model as model_lib
from repro.models import params as P
from repro.serve import full_session_program


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b",
                    choices=ASSIGNED)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)          # CPU-sized, same family
    if cfg.family == "audio":
        sys.exit("audio serving needs the encoder frontend batch — pick "
                 "an LM arch (see tests/test_system.py for whisper decode)")
    print(f"serving {args.arch} (reduced config: {cfg.n_layers}L "
          f"d={cfg.d_model})")
    params = P.init(jax.random.PRNGKey(0), model_lib.lm_specs(cfg))

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0,
        cfg.vocab_size)

    # one program per session horizon: caches are born at total_len
    total = args.prompt_len + args.new_tokens
    prog = full_session_program(cfg, total)

    t0 = time.time()
    tok, kv = prog.prefill(params, prompts)
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    for i in range(args.new_tokens - 1):
        tok, kv = prog.decode(params, kv, tok,
                              jnp.int32(args.prompt_len + i))
        out.append(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"prefill {args.batch}x{args.prompt_len} tokens: "
          f"{t_prefill * 1e3:.0f} ms")
    print(f"decode {gen.shape[1]} tokens/seq: "
          f"{t_decode * 1e3 / max(gen.shape[1], 1):.1f} ms/token (CPU)")
    print("generated token ids (seq 0):", gen[0].tolist())


if __name__ == "__main__":
    main()
