"""Elasticity demo (paper Fig. 5 in miniature): replay a synthetic
preemption trace over a 24-peer swarm and compare throughput with and
without adaptive rebalancing.

    PYTHONPATH=src python examples/elastic_failures.py
"""
import sys

sys.path.insert(0, "src")

from repro.core import SwarmRunner, SwarmConfig
from repro.core.faults import synth_preemptible_trace, active_counts
from repro.models.config import ArchConfig
from repro.optim import adamw

MODEL = ArchConfig(name="elastic-demo", family="dense", n_layers=4,
                   d_model=4096, n_heads=32, n_kv_heads=32, d_ff=16384,
                   vocab_size=50257, tie_embeddings=True)
HORIZON = 3600.0


def run(rebalance_T: float, trace, overlap: bool = False):
    scfg = SwarmConfig(n_stages=4, microbatch_size=1, seq_len=512,
                       global_batch=1024, n_trainers=72,
                       rebalance_period=rebalance_T, codec="int8",
                       overlap=overlap)
    r = SwarmRunner(MODEL, scfg, adamw(), numeric=False, seed=0)
    r.build(peers_per_stage=6)
    r.apply_trace(trace)
    r.run(until=HORIZON)
    return r


def main():
    trace = synth_preemptible_trace(horizon_s=HORIZON, target_peers=24,
                                    mean_lifetime_s=1200.0, seed=3)
    counts = active_counts(trace, 24, HORIZON, dt=600.0)
    print("active peers over the hour:", list(counts))
    for T, overlap, tag in ((0.0, False, "no rebalancing "),
                            (60.0, False, "rebalance T=60 "),
                            (60.0, True, "T=60 + overlap ")):
        r = run(T, trace, overlap=overlap)
        print(f"{tag}: {r.throughput():.2f} samples/s, "
              f"{r.metrics['failures']} failures, "
              f"{r.metrics['joins']} joins, "
              f"{r.metrics['migrations']} migrations, "
              f"{r.metrics['recomputed_microbatches']} recomputed "
              f"microbatches (exactly-once ledger)")
        idle = r.metrics["peer_idle_s"]
        mean_idle = sum(idle.values()) / max(len(idle), 1)
        print(f"{' ' * len(tag)}  overlap fraction "
              f"{r.metrics['overlap_fraction']:.2f}, "
              f"{r.metrics['inflight_bytes'] / 1e9:.2f} GB in flight, "
              f"mean peer idle {mean_idle:.0f}s")


if __name__ == "__main__":
    main()
