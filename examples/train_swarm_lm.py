"""End-to-end driver: train an LM with SWARM parallelism and compare the
loss curve against plain synchronous data-parallel training — the Fig. 4
convergence-parity experiment in miniature.

Default config is CPU-sized (runs in ~2 min); ``--model 100m`` selects a
~100M-parameter model (slow on CPU, sized for a real accelerator).

    PYTHONPATH=src python examples/train_swarm_lm.py [--steps 12]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.core import SwarmRunner, SwarmConfig
from repro.models.config import ArchConfig
from repro.optim import adamw, delayed_parameter_updates
from repro.train.steps import make_train_step, make_state
from repro.data.synthetic import SyntheticLM

SMALL = ArchConfig(name="lm-small", family="dense", n_layers=4,
                   d_model=128, n_heads=4, n_kv_heads=4, d_ff=512,
                   vocab_size=512, head_dim=32, compute_dtype="float32",
                   param_dtype="float32")
LM100M = ArchConfig(name="lm-100m", family="dense", n_layers=12,
                    d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
                    vocab_size=50304, compute_dtype="float32",
                    param_dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--model", choices=["small", "100m"], default="small")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--dpu", action="store_true",
                    help="delayed parameter updates (paper §3.2)")
    ap.add_argument("--overlap", action="store_true",
                    help="async tick: in-flight boundary transfers")
    ap.add_argument("--staleness", type=int, default=0,
                    help="bounded-staleness All-Reduce windows (implies "
                         "DPU inside the runner)")
    args = ap.parse_args()
    cfg = SMALL if args.model == "small" else LM100M

    opt = adamw(lr=3e-3)
    if args.dpu:
        opt = delayed_parameter_updates(opt)

    # --- SWARM run (2 stages x 2 peers, int8 boundaries, real math)
    scfg = SwarmConfig(n_stages=2, microbatch_size=args.batch // 4,
                       seq_len=args.seq, global_batch=args.batch,
                       n_trainers=4, rebalance_period=0.0, codec="int8",
                       max_steps=args.steps, overlap=args.overlap,
                       staleness=args.staleness)
    t0 = time.time()
    runner = SwarmRunner(cfg, scfg, opt, numeric=True, seed=0)
    runner.build(peers_per_stage=2)
    metrics = runner.run(until=1e12)
    swarm_losses = metrics["loss"]
    t_swarm = time.time() - t0

    # --- synchronous reference (same data, same optimizer; a
    # staleness>0 runner wraps its optimizer in DPU internally, so the
    # reference must too)
    opt_ref = adamw(lr=3e-3)
    if args.dpu or args.staleness > 0:
        opt_ref = delayed_parameter_updates(opt_ref)
    state = make_state(cfg, opt_ref, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, opt_ref))
    ds = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=17)
    ref_losses = []
    t0 = time.time()
    for i in range(args.steps):
        state, m = step_fn(state, ds.batch(i))
        ref_losses.append(float(m["ce"]))
    t_ref = time.time() - t0

    print(f"{'step':>5} {'SWARM':>9} {'sync-DP':>9}")
    for i, (a, b) in enumerate(zip(swarm_losses, ref_losses)):
        print(f"{i + 1:>5} {a:>9.4f} {b:>9.4f}")
    print(f"\nSWARM wall {t_swarm:.1f}s (simulated cluster), "
          f"reference wall {t_ref:.1f}s")
    idle = metrics["peer_idle_s"]
    mean_idle = sum(idle.values()) / max(len(idle), 1)
    print(f"async tick: overlap fraction "
          f"{metrics['overlap_fraction']:.2f}, "
          f"{metrics['inflight_bytes'] / 1e6:.2f} MB in flight, "
          f"mean peer idle {mean_idle:.1f}s (virtual)")
    print("convergence parity (Fig. 4):",
          "OK" if abs(swarm_losses[-1] - ref_losses[-1]) < 0.25 else
          "DIVERGED")


if __name__ == "__main__":
    main()
